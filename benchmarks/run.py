"""Benchmark harness — one benchmark per paper figure/table plus the
trainer-communication and kernel tables.  Prints CSV blocks and writes
them under experiments/bench/.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import csv
import io
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)


def emit(name: str, rows, outdir: str):
    if not rows:
        print(f"# {name}: no rows")
        return
    fields = list(dict.fromkeys(k for r in rows for k in r))
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=fields, restval="")
    w.writeheader()
    for r in rows:
        w.writerow(r)
    text = buf.getvalue()
    print(f"\n# ===== {name} =====")
    print(text)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"{name}.csv"), "w") as f:
        f.write(text)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 trial per config (CI mode)")
    ap.add_argument("--out", default="experiments/bench")
    args, _ = ap.parse_known_args()
    trials = 1 if args.quick else 2

    from benchmarks.paper_figs import bench_fig1, bench_fig2
    from benchmarks.complexity import (bench_complexity_table,
                                       bench_trainer_comm)
    from benchmarks.kernel_bench import (bench_altgdmin_engine,
                                         bench_compression,
                                         bench_consensus, bench_kernels)
    from benchmarks.system_bench import bench_system
    from benchmarks.serving_bench import bench_serving
    from benchmarks.scale_bench import bench_scale

    t0 = time.time()
    engine_rows = bench_altgdmin_engine(quick=args.quick)
    emit("altgdmin_engine", engine_rows, args.out)
    consensus_rows = bench_consensus(quick=args.quick)
    emit("consensus_combine", consensus_rows, args.out)
    compression_rows = bench_compression(quick=args.quick)
    emit("compression_combine", compression_rows, args.out)
    system_rows = bench_system(quick=args.quick)
    emit("system_dropout", system_rows, args.out)
    serving_rows = bench_serving(quick=args.quick)
    emit("serving_throughput", serving_rows, args.out)
    scale_rows = bench_scale(quick=args.quick)
    emit("scale_nodes", scale_rows, args.out)
    # the virtual-mesh tier rows also get their own CSV (uploaded as a
    # CI artifact next to the JSON — the per-PR scale trajectory)
    emit("scale_virtual_mesh",
         [r for r in scale_rows if r.get("section") == "virtual_mesh"],
         args.out)
    bench_json = {
        "benchmark": "altgdmin_engine",
        "description": "fused node-batched AltGDmin iteration engine: "
                       "µs per outer iteration (min-B + gradient) and "
                       "model FLOPs, fused vs unfused vs reference",
        "note": "Pallas backends run in interpret mode on CPU — model "
                "FLOPs are the hardware-independent trajectory metric",
        "quick": args.quick,
        "rows": engine_rows,
        "consensus": {
            "description": "mesh-runtime gossip combine, µs/round: the "
                           "fused (K+1)-way gossip_combine dispatch "
                           "(uniform ring weights AND the per-shift "
                           "weighted form arbitrary topologies lower "
                           "to) vs the unfused K-sweep weighted-sum "
                           "chain",
            "rows": consensus_rows,
        },
        "compression": {
            "description": "compressed consensus rules (topk/quantized/"
                           "event gossip with reference-copy error "
                           "feedback) vs dense gossip at the paper's "
                           "(d=100, r=4, L=16) shape: declared "
                           "CommSignature bytes/iter + reduction factor "
                           "and µs/round of the fused vs exact "
                           "simulator lowering; the event rule also "
                           "reports its measured send fraction",
            "rows": compression_rows,
        },
        "system": {
            "description": "system-realism layer: convergence vs "
                           "SIMULATED seconds (event-driven clock) — "
                           "dense dif_altgdmin under an always-on "
                           "SystemSpec vs the dropout-tolerant "
                           "dif_partial/dif_stale/dif_pushsum under a "
                           "seeded 30%-dropout Bernoulli availability "
                           "schedule, shared materialization",
            "rows": system_rows,
        },
        "serving": {
            "description": "few-shot personalization serving: the "
                           "packed batched min-B solve — requests/sec "
                           "× batch × d frontier with p50/p99 "
                           "closed-loop latency (section=throughput), "
                           "b_new recovery error vs samples-per-user "
                           "T_new (section=recovery), and the "
                           "drifting-U continual mode (θ̂ error falls "
                           "as fresher checkpoints publish, "
                           "section=drifting)",
            "rows": serving_rows,
        },
        "scale": {
            "description": "sparse consensus path at large L: a full "
                           "dif_altgdmin run through the runner on the "
                           "sparse simulator substrate at L=100k "
                           "(quick: 10k) over a Barabási–Albert graph "
                           "— µs/outer-iter + peak RSS + edge count "
                           "(section=large_L), the sparse segment-sum "
                           "vs dense stacked-matmul mix crossover "
                           "(section=sparse_vs_dense), RCM "
                           "shift-count pruning of the mesh "
                           "decomposition (section=rcm), and the "
                           "virtual-node mesh tier at the same L — "
                           "three non-gossip solver programs "
                           "(exact_diffusion / dif_topk / dif_partial) "
                           "on 8 fake devices through the one program "
                           "lowering (section=virtual_mesh)",
            "rows": scale_rows,
        },
    }
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for path in (os.path.join(args.out, "BENCH_altgdmin.json"),
                 os.path.join(repo_root, "BENCH_altgdmin.json")):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(bench_json, f, indent=1)
    print(f"[engine bench done in {time.time()-t0:.0f}s → "
          f"BENCH_altgdmin.json]")
    t0 = time.time()
    emit("fig1_convergence_vs_Tcon", bench_fig1(trials), args.out)
    print(f"[fig1 done in {time.time()-t0:.0f}s]")
    t1 = time.time()
    emit("fig2_connectivity", bench_fig2(trials), args.out)
    print(f"[fig2 done in {time.time()-t1:.0f}s]")
    emit("sec3_complexity_dif_vs_dec", bench_complexity_table(), args.out)
    emit("trainer_comm_per_step", bench_trainer_comm(), args.out)
    emit("kernel_micro", bench_kernels(), args.out)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
