"""CLI sweep driver — shard ExperimentSpec grids across worker processes.

The figure benchmarks (:mod:`benchmarks.paper_figs`) run their sweep
cells sequentially inside one process; this driver externalizes the
grid instead: ``emit`` serializes a figure's cells (one JSON object per
cell, via :func:`paper_figs.specs_for_figure` — the specs are
round-trip safe by construction), ``run`` executes them one PROCESS per
cell (a crashed or OOM-killed cell loses only itself) and merges the
per-cell rows into one CSV, and ``cell`` is the internal child entry
point.  Because every cell is a plain spec JSON, grids can also be
hand-written or generated elsewhere — anything ``ExperimentSpec.
from_json`` accepts, including SystemSpec fault schedules.

    python -m benchmarks.sweep emit --figure fig1 --out grid.json
    python -m benchmarks.sweep run --specs grid.json --out sweep.csv \
        --jobs 4

``--in-process`` runs the cells in this process (no subprocess spawn) —
the test-suite path, and useful under a debugger.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import subprocess
import sys
import tempfile
from concurrent.futures import ThreadPoolExecutor

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

CHECKPOINTS = (0.0, 0.25, 0.5, 0.75, 1.0)
FIELDS = ("config", "solver", "substrate", "iteration",
          "subspace_distance", "time_s", "time_axis_source")


def _figure_cells(figure: str, trial: int) -> list[dict]:
    from benchmarks.paper_figs import ALGORITHMS, specs_for_figure
    from repro.configs.paper import EXPERIMENT1_SMALL, EXPERIMENT2_SMALL
    configs = {"fig1": EXPERIMENT1_SMALL, "fig2": EXPERIMENT2_SMALL}[figure]
    specs = specs_for_figure(configs, trial=trial)
    # one key per (config, solver) cell, in specs_for_figure's order —
    # the same cfg.seed + trial derivation run_experiment_grid uses, so
    # the sharded sweep reproduces the in-process benchmark's cells
    keys = [cfg.seed + trial for cfg in configs for _ in ALGORITHMS]
    return [{"key": k, "spec": json.loads(s.to_json())}
            for k, s in zip(keys, specs)]


def run_cell(cell: dict) -> list[dict]:
    """Execute one sweep cell in THIS process and return its CSV rows."""
    from repro.api import ExperimentSpec, run_experiment
    spec = ExperimentSpec.from_json(json.dumps(cell["spec"]))
    trace = run_experiment(spec, key=int(cell.get("key", 0)))
    rows = []
    n = len(trace.sd_max)
    for frac in CHECKPOINTS:
        i = min(int(frac * (n - 1)), n - 1)
        rows.append({
            "config": spec.name or spec.solver.name,
            "solver": spec.solver.name,
            "substrate": spec.substrate,
            "iteration": i,
            "subspace_distance": float(trace.sd_max[i]),
            "time_s": float(trace.time_axis[i]),
            "time_axis_source": trace.time_axis_source,
        })
    return rows


def _run_cell_subprocess(cell: dict) -> list[dict]:
    """Execute one cell in a CHILD process (crash isolation) and parse
    the row JSON it prints on its last stdout line."""
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False,
                                     dir=None) as f:
        json.dump(cell, f)
        path = f.name
    try:
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.sweep", "cell",
             "--spec", path],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sweep cell failed (rc={proc.returncode}):\n"
                f"{proc.stderr[-2000:]}")
        return json.loads(proc.stdout.strip().splitlines()[-1])
    finally:
        os.unlink(path)


def cmd_emit(args) -> None:
    cells = _figure_cells(args.figure, args.trial)
    with open(args.out, "w") as f:
        json.dump(cells, f, indent=1)
    print(f"wrote {len(cells)} cells to {args.out}")


def cmd_run(args) -> None:
    with open(args.specs) as f:
        cells = json.load(f)
    worker = run_cell if args.in_process else _run_cell_subprocess
    if args.in_process or args.jobs <= 1:
        results = [worker(c) for c in cells]
    else:
        with ThreadPoolExecutor(max_workers=args.jobs) as pool:
            results = list(pool.map(worker, cells))
    rows = [row for cell_rows in results for row in cell_rows]
    with open(args.out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=FIELDS)
        w.writeheader()
        w.writerows(rows)
    print(f"{len(cells)} cells -> {len(rows)} rows -> {args.out}")


def cmd_cell(args) -> None:
    with open(args.spec) as f:
        cell = json.load(f)
    print(json.dumps(run_cell(cell)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.sweep",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("emit", help="serialize a figure's sweep grid")
    p.add_argument("--figure", choices=("fig1", "fig2"), required=True)
    p.add_argument("--trial", type=int, default=0)
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_emit)

    p = sub.add_parser("run", help="execute a grid, one process per cell")
    p.add_argument("--specs", required=True, help="JSON grid from emit")
    p.add_argument("--out", required=True, help="merged CSV path")
    p.add_argument("--jobs", type=int, default=2)
    p.add_argument("--in-process", action="store_true",
                   help="run cells in this process (tests / debugging)")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("cell", help="internal: run one cell, print rows")
    p.add_argument("--spec", required=True, help="single-cell JSON file")
    p.set_defaults(fn=cmd_cell)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
