"""Serving benchmark — the batched min-B inference subsystem.

Three sections, one CSV (``serving_throughput.csv``) and one JSON block
(``BENCH_altgdmin.json["serving"]``):

  * ``throughput`` — the requests/sec × batch size × d frontier of the
    packed solve (µs per dispatch, amortized µs per request), plus
    p50/p99 end-to-end latency and shed counts from a closed-loop run
    of the deadline batcher at ~70% of the measured capacity;
  * ``recovery``   — b_new recovery error vs samples-per-user T_new
    (noisy responses, served from the TRUE representation: the
    few-shot-generalization curve of shared-representation MTL);
  * ``drifting``   — the continual mode: a dif_altgdmin run publishes U
    checkpoints every k iterations; a fixed eval cohort is re-served
    from each snapshot, and the θ̂ error falls as fresher U's publish.

µs numbers are CPU wall-clock (xla-ref off-TPU) — like the engine
bench, the frontier SHAPE (batching amortization, d scaling) is the
portable signal, absolute µs are not.

  PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, run_experiment)
from repro.checkpoint import latest_step
from repro.serving import (RequestGenerator, ServingEngine,
                           load_representation, run_closed_loop)


def _orthonormal(key, d, r, dtype=jnp.float64):
    return jnp.linalg.qr(jax.random.normal(key, (d, r), dtype))[0]


def _time_packed(engine, X, y, reps):
    engine.solve_packed(X, y)[0].block_until_ready()          # warm the jit
    t0 = time.perf_counter()
    for _ in range(reps):
        B, _ = engine.solve_packed(X, y)
    jax.block_until_ready(B)
    return (time.perf_counter() - t0) / reps


def _time_ragged(engine, X_list, y_list, reps):
    """End-to-end request path (numpy packing + dispatch) — what the
    closed loop actually pays per batch, so the offered load is
    calibrated against it rather than the bare packed dispatch."""
    engine.solve(X_list, y_list)                              # warm the jit
    t0 = time.perf_counter()
    for _ in range(reps):
        B, _, _ = engine.solve(X_list, y_list)
    jax.block_until_ready(B)
    return (time.perf_counter() - t0) / reps


def _throughput_rows(quick: bool):
    rows = []
    r, t_new = 4, 16
    reps = 10 if quick else 50
    n_load = 200 if quick else 800
    key = jax.random.PRNGKey(0)
    for d in ((100,) if quick else (100, 256)):
        U = _orthonormal(jax.random.fold_in(key, d), d, r)
        for batch in (1, 8, 32):
            eng = ServingEngine(U, max_batch=batch)
            X = jax.random.normal(jax.random.fold_in(key, 7 * d + batch),
                                  (batch, t_new, d), U.dtype)
            y = jax.random.normal(jax.random.fold_in(key, 9 * d + batch),
                                  (batch, t_new), U.dtype)
            s_per_batch = _time_packed(eng, X, y, reps)
            req_per_s = batch / s_per_batch
            # closed loop at ~70% of the END-TO-END capacity (the
            # ragged request path: numpy packing + dispatch), so the
            # system is stable; latency keeps a queueing component
            s_loop = _time_ragged(eng, [np.asarray(X[i]) for i in range(batch)],
                                  [np.asarray(y[i]) for i in range(batch)],
                                  max(reps // 5, 3))
            gen = RequestGenerator(np.asarray(U), t_new=t_new,
                                   rate_hz=0.7 * batch / s_loop, seed=0)
            report = run_closed_loop(eng, gen.generate(n_load),
                                     max_wait_s=4.0 * s_loop,
                                     queue_capacity=max(4 * batch, 16))
            pct = report.latency_percentiles((50, 99))
            rows.append({
                "section": "throughput", "d": d, "r": r, "t_new": t_new,
                "batch": batch, "backend": eng.engine.backend,
                "us_per_dispatch": 1e6 * s_per_batch,
                "us_per_request": 1e6 * s_per_batch / batch,
                "req_per_s": req_per_s,
                "p50_latency_ms": 1e3 * pct["p50"],
                "p99_latency_ms": 1e3 * pct["p99"],
                "n_requests": len(report.records),
                "n_shed": report.n_shed,
                "mean_batch": float(np.mean(report.batch_sizes)),
            })
    return rows


def _recovery_rows(quick: bool):
    rows = []
    d, r, noise = 100, 4, 0.5
    n_eval = 64 if quick else 256
    key = jax.random.PRNGKey(1)
    U_star = _orthonormal(key, d, r)
    for t_new in (4, 8, 16, 32, 64):
        eng = ServingEngine(U_star, max_batch=n_eval)
        gen = RequestGenerator(np.asarray(U_star), t_new=t_new,
                               noise_std=noise, seed=3)
        reqs = gen.generate(n_eval)
        _, theta, _ = eng.solve([q.X for q in reqs], [q.y for q in reqs])
        theta = np.asarray(theta)
        errs = [np.linalg.norm(theta[i] - q.theta_star)
                / np.linalg.norm(q.theta_star)
                for i, q in enumerate(reqs)]
        rows.append({"section": "recovery", "d": d, "r": r,
                     "t_new": t_new, "noise_std": noise,
                     "n_requests": n_eval,
                     "mean_err": float(np.mean(errs)),
                     "p90_err": float(np.percentile(errs, 90))})
    return rows


def _drifting_rows(quick: bool):
    """Train with checkpoint publishing, then re-serve one fixed eval
    cohort from every published U — the b_new error vs checkpoint curve
    of the drifting-U continual mode."""
    T_GD, every = (30, 10) if quick else (60, 15)
    spec = ExperimentSpec(
        name="serving_drift",
        problem=ProblemSpec(d=60, T=48, r=4, n=24, L=8, kappa=2.0),
        topology=TopologySpec(family="erdos_renyi", p=0.5, seed=1),
        init=InitSpec(T_pm=20, T_con=10),
        solver=SolverSpec(name="dif_altgdmin", T_GD=T_GD, T_con=3))
    rows = []
    with tempfile.TemporaryDirectory() as ckdir:
        trace = run_experiment(spec, key=0, checkpoint_every=every,
                               checkpoint_dir=ckdir)
        d, r = spec.problem.d, spec.problem.r
        U_star = np.asarray(trace.materialized.problem.U_star)
        gen = RequestGenerator(U_star, t_new=16, seed=5)
        reqs = gen.generate(32 if quick else 64)
        eng = None
        for step in range(0, T_GD + 1, every):
            U = load_representation(ckdir, step, d=d, r=r,
                                    dtype=jnp.float64)
            if eng is None:
                eng = ServingEngine(U, max_batch=len(reqs), version=step)
            else:
                eng.update_representation(U, version=step)
            _, theta, _ = eng.solve([q.X for q in reqs],
                                    [q.y for q in reqs])
            theta = np.asarray(theta)
            errs = [np.linalg.norm(theta[i] - q.theta_star)
                    / np.linalg.norm(q.theta_star)
                    for i, q in enumerate(reqs)]
            rows.append({"section": "drifting", "checkpoint_step": step,
                         "d": d, "r": r, "t_new": 16,
                         "sd_max": (float(trace.sd_max[step - 1])
                                    if step else float("nan")),
                         "mean_err": float(np.mean(errs))})
        assert latest_step(ckdir) == T_GD
    return rows


def bench_serving(quick: bool = False):
    rows = _throughput_rows(quick)
    rows += _recovery_rows(quick)
    rows += _drifting_rows(quick)
    return rows


if __name__ == "__main__":
    import argparse
    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for row in bench_serving(quick=args.quick):
        print(row)
